"""Quickstart: the paper's pipeline end-to-end in ~a minute on CPU.

1. Verify the decentralization theorem numerically (§4.3, exact).
2. Partition a synthetic multimodal corpus with balanced spherical k-means.
3. Train K=2 tiny experts independently + a compute-matched dense baseline.
4. Serve with the centroid router and compare ensemble vs dense NLL.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core.decentralize import ClusterSplit, decomposition_residual
from repro.core.dfm import enumerate_states, n_states
from repro.core.router import RouterConfig
from repro.data.partition import partition_dataset
from repro.data.synthetic import SyntheticConfig, SyntheticMultimodal
from repro.configs.base import get_smoke_config
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.serve.api import SamplingParams
from repro.serve.ensemble_engine import DecentralizedServer
from repro.train.trainer import (TrainConfig, init_train_state,
                                 train_host_loop)
from repro.data.pipeline import LoaderConfig, ShardLoader


def step1_theorem():
    print("== 1. Decentralization theorem (exact, on [d]^N) ==")
    d, N, K, mask = 3, 3, 2, 2
    rng = np.random.default_rng(0)
    states = enumerate_states(d, N)
    q = rng.random(n_states(d, N))
    q[(states == mask).any(1)] = 0.0
    q /= q.sum()
    split = ClusterSplit(q=jnp.asarray(q),
                         assignment=rng.integers(0, K, q.shape[0]), K=K)
    for t in range(N):
        res = float(decomposition_residual(split, 0, t, d, N, mask))
        print(f"  t={t}: ‖u_global − Σ_k r_k u_k‖∞ = {res:.2e}")
        assert res < 1e-12
    print("  ✓ global velocity == router-weighted expert velocities\n")


def step2_to_4():
    print("== 2. Partition a clustered multimodal corpus ==")
    corpus = SyntheticMultimodal(SyntheticConfig(
        vocab=64, seq_len=32, n_samples=512, n_latent=2, seed=0))
    part = partition_dataset(corpus.all_features(), 2,
                             router_config=RouterConfig(top_k=1))
    print(f"  shards: {[len(s) for s in part.shards]} (balanced)\n")

    print("== 3. Train 2 independent experts + dense baseline ==")
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    tc = TrainConfig(opt=opt)

    def train(subset, batch, seed, offset=0):
        state = init_train_state(model, jax.random.PRNGKey(seed), opt)
        loader = ShardLoader(corpus, LoaderConfig(batch_size=batch),
                             subset=subset, offset=offset)
        state, hist = train_host_loop(model, state, loader, 60, tc,
                                      log_every=30)
        return state["params"], hist[-1]["loss"]

    dense_params, dense_loss = train(None, 8, 0)
    print(f"  dense final loss     : {dense_loss:.3f}")
    experts = []
    for k in range(2):
        p, l = train(part.shards[k], 4, 100 + k, offset=10_000 * k)
        experts.append(p)
        print(f"  expert {k} final loss  : {l:.3f} (trained in isolation)")

    print("\n== 4. Serve: routed ensemble vs dense ==")
    server = DecentralizedServer(model, experts, part.router, cache_len=40)
    batch_np = corpus.sample_batch(32, step=777)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()
             if k in ("tokens", "labels", "features")}
    ens_nll = float(server.ensemble_eval_nll(batch))
    logits = model.forward(dense_params, {k: batch[k]
                                          for k in ("tokens", "labels")})
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    d_nll = float(-jnp.take_along_axis(
        logp[:, :-1], batch["labels"][:, 1:, None], -1).mean())
    print(f"  dense NLL    = {d_nll:.3f}")
    print(f"  ensemble NLL = {ens_nll:.3f}  (top-1 routed, compute-matched)")
    # SamplingParams is the same object the slot engines consume — the
    # seed derives the sampling key (temperature > 0 → stochastic)
    toks = server.generate_top1(batch, SamplingParams(max_new=8,
                                                      temperature=1.0,
                                                      seed=1))
    print(f"  sample generation: {toks[0].tolist()}")


if __name__ == "__main__":
    step1_theorem()
    step2_to_4()
    print("\nquickstart complete ✓")
